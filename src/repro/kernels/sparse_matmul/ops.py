"""Public op: sparse linear layer over a CompressedLinear weight."""
from __future__ import annotations

import jax.numpy as jnp

from ...core.sparsity import CompressedLinear
from .kernel import block_sparse_matmul
from .ref import block_sparse_matmul_ref


def sparse_linear(
    x: jnp.ndarray,
    cl: CompressedLinear,
    *,
    bm: int = 128,
    out_dtype=jnp.float32,
    interpret: bool = False,
    use_kernel: bool = True,
) -> jnp.ndarray:
    """y = x @ W for compile-time-compacted W (optionally int8+scales).

    ``x`` may be (..., K); leading dims are flattened to M for the kernel.
    ``use_kernel=False`` falls back to the jnp oracle (CPU prod path).
    """
    pat = cl.pattern
    K, N = pat.shape
    lead = x.shape[:-1]
    xm = x.reshape(-1, K)
    kwargs = dict(
        block_rows=pat.block_rows,
        block_cols=pat.block_cols,
        n_row_blocks=pat.bitmap.shape[0],
        n_col_blocks=pat.bitmap.shape[1],
        scales=cl.scales,
        out_dtype=out_dtype,
    )
    if use_kernel:
        M = xm.shape[0]
        pad = (-M) % bm
        if pad:
            xm = jnp.pad(xm, ((0, pad), (0, 0)))
        y = block_sparse_matmul(xm, cl.blocks, bm=bm, interpret=interpret, **kwargs)
        if pad:
            y = y[:M]
    else:
        y = block_sparse_matmul_ref(xm, cl.blocks, **kwargs)
    return y.reshape(*lead, N)
