"""Public op: sparse linear layer over a CompressedLinear weight."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from ...core.sparsity import CompressedLinear
from .kernel import (
    _pad_rows,
    _sublane,
    block_sparse_matmul,
    block_sparse_matmul_decode,
)
from .ref import block_sparse_matmul_ref


def sparse_linear(
    x: jnp.ndarray,
    cl: CompressedLinear,
    *,
    bm: Optional[int] = None,
    bias: Optional[jnp.ndarray] = None,
    activation: Optional[str] = None,
    out_dtype=jnp.float32,
    interpret: bool = False,
    use_kernel: bool = True,
) -> jnp.ndarray:
    """y = act(x @ W + b) for compile-time-compacted W (optionally int8+scales).

    ``x`` may be (..., K); leading dims are flattened to M for the kernel.
    ``bm=None`` auto-selects the row tile: decode-thin M goes through the
    batched-RHS entry point, prefill-wide M through the 128-row tile.
    ``use_kernel=False`` falls back to the jnp oracle (CPU prod path).

    A bit-packed ``cl`` (``cl.blocks`` a PackedTensor — int4 codes two per
    byte, or int2 codes four per byte) rides the kernel's packed prologue
    when the container is packed along a bk axis the code count divides
    (weights travel HBM->VMEM at a half / quarter of the bytes); any
    other packing falls back to a trace-time unpack into the identical
    int8 path — bitwise-equal numerics either way.
    """
    pat = cl.pattern
    K, N = pat.shape
    blocks = cl.blocks
    packed_kernel = False
    if cl.packed:
        bk_ax = cl.blocks.axis % 3
        per_byte = cl.blocks.per_byte
        if use_kernel and bk_ax == 1 and pat.block[0] % per_byte == 0:
            blocks, packed_kernel = cl.blocks.data, cl.blocks.container
        else:
            blocks = cl.block_values()  # trace-time unpack, same codes
    if bm is not None:
        sub = _sublane(x.dtype)
        if bm % sub or not 0 < bm <= 128:
            # an illegal row tile dies inside Mosaic lowering with an opaque
            # error on the compiled path — fail loudly at the op boundary
            raise ValueError(
                f"illegal row tile bm={bm} for x dtype {jnp.dtype(x.dtype).name}"
                f" — legal: multiples of {sub} up to 128 "
                f"({list(range(sub, 129, sub))})")
    if x.shape[-1] != K:
        raise ValueError(
            f"sparse_linear: activation feature dim {x.shape[-1]} does not "
            f"match the compiled weight's K={K} (= {pat.bitmap.shape[0]} row "
            f"blocks x {pat.block[0]}); a bare reshape would silently fold "
            "batch rows into features — fix the caller's shape")
    lead = x.shape[:-1]
    xm = x.reshape(-1, K)
    kwargs = dict(
        block_rows=pat.block_rows,
        block_cols=pat.block_cols,
        n_row_blocks=pat.bitmap.shape[0],
        n_col_blocks=pat.bitmap.shape[1],
        scales=cl.scales,
        bias=bias,
        activation=activation,
        out_dtype=out_dtype,
    )
    if use_kernel:
        M = xm.shape[0]
        if bm is None and M < 128:
            y = block_sparse_matmul_decode(xm, blocks, interpret=interpret,
                                           packed=packed_kernel, **kwargs)
        else:
            bm = 128 if bm is None else bm
            xm, M = _pad_rows(xm, bm)
            y = block_sparse_matmul(xm, blocks, bm=bm, interpret=interpret,
                                    packed=packed_kernel, **kwargs)[:M]
    else:
        y = block_sparse_matmul_ref(xm, blocks, **kwargs)
    return y.reshape(*lead, N)
