"""Pure-jnp oracle for the block-sparse matmul kernel."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from .kernel import ACTIVATIONS, _check_activation, apply_activation


def block_sparse_matmul_ref(
    x: jnp.ndarray,
    blocks: jnp.ndarray,
    block_rows,
    block_cols,
    *,
    n_row_blocks: int,
    n_col_blocks: int,
    scales: Optional[jnp.ndarray] = None,
    bias: Optional[jnp.ndarray] = None,
    activation: Optional[str] = None,
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    """Scatter blocks back to dense and matmul in f32; epilogue applies the
    same bias + activation formulas the kernel fuses (kernel.ACTIVATIONS)."""
    _check_activation(activation)
    P, bk, bn = blocks.shape
    K, N = n_row_blocks * bk, n_col_blocks * bn
    w = blocks.astype(jnp.float32)
    if scales is not None:
        s = scales.reshape(n_col_blocks, bn).astype(jnp.float32)
        w = w * s[np.asarray(block_cols)][:, None, :]
    dense = jnp.zeros((n_row_blocks, n_col_blocks, bk, bn), jnp.float32)
    if P:
        dense = dense.at[np.asarray(block_rows), np.asarray(block_cols)].set(w)
    dense = dense.transpose(0, 2, 1, 3).reshape(K, N)
    y = jnp.dot(x.astype(jnp.float32), dense)
    if bias is not None:
        y = y + bias.reshape(N).astype(jnp.float32)[None, :]
    if activation is not None:
        y = apply_activation(y, activation)
    return y.astype(out_dtype)
