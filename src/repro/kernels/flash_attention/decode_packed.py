"""Fused packed-KV decode/prefill attention — Pallas kernel + jnp twin.

The serving cache stores K/V as bit-packed int4 codes (two per uint8
byte, ``int4x2``) with per-(slot, position, kv-head) f32 scales.  Before
this kernel, every decode step unpacked the *entire* ``max_len`` history
to f32 and ran plain softmax attention over it — an O(L·Dh) per-step
materialisation tax.  Here the packed uint8 tiles are streamed
HBM→VMEM with the double-buffered DMA prologue from the quant-matmul
kernel, nibble-decoded and dequantised in-register per tile, and
attended with an online softmax that only touches tiles below the
slot's live length.  The unpacked f32 cache copy never exists.

Two entry points:

* :func:`packed_decode_attention` — the Pallas kernel, single query row
  per slot (decode).  Grid ``(B·Hkv, n_t)`` with the kv-tile index
  innermost; online-softmax state (m, l, acc) lives in VMEM scratch and
  the output is emitted at the last tile.  Dead tiles (``it·bt >= L``)
  are skipped entirely — no DMA is issued and the softmax state is
  untouched, so results are invariant to the cache extent at fixed
  ``bt``.
* :func:`tiled_packed_attention` — the jnp twin, additionally batched
  over a chunk axis C with per-row lengths (the prefill read).  It
  replays the *same* op order tile by tile (shared ``unpack_int4``,
  same ``NEG_INF`` masking, same explicit dead-tile skip, one final
  ``acc / max(l, 1e-30)`` division), so kernel and twin are bitwise
  identical — asserted by tests on every dispatch leg.  With
  ``packed=False`` the twin reads int8 codes directly (the unpacked
  ``int4`` cache mode), which keeps int4 and int4x2 serving
  bitwise-equal.

Both paths compute f32 straight from codes × scales; the old read's
intermediate cast of the dequantised cache to the model compute dtype
is gone (documented in docs/architecture.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.quant import unpack_int4

__all__ = ["packed_decode_attention", "tiled_packed_attention"]

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, ks_ref, vs_ref, kp_hbm, vp_hbm, o_ref,
                   kbuf, vbuf, ksem, vsem, m_ref, l_ref, acc_ref, *,
                   bt: int, n_t: int, Dh: int, Hkv: int):
    bh = pl.program_id(0)
    it = pl.program_id(1)
    b = bh // Hkv
    h = bh % Hkv
    length = len_ref[b]

    def _stream(j, slot):
        pltpu.make_async_copy(kp_hbm.at[b, pl.ds(j * bt, bt), h],
                              kbuf.at[slot], ksem.at[slot]).start()
        pltpu.make_async_copy(vp_hbm.at[b, pl.ds(j * bt, bt), h],
                              vbuf.at[slot], vsem.at[slot]).start()

    @pl.when(it == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        _stream(0, 0)

    slot = jax.lax.rem(it, 2)
    live = (it * bt) < length

    # prefetch the next live tile into the other buffer while this one
    # computes — the PR 6 double-buffered prologue pattern
    @pl.when(((it + 1) < n_t) & (((it + 1) * bt) < length))
    def _prefetch():
        _stream(it + 1, 1 - slot)

    # tile 0's copy is always started (grid warm-up), so always wait on
    # it; later tiles only started a copy when live
    @pl.when((it == 0) | live)
    def _wait():
        pltpu.make_async_copy(kp_hbm.at[b, pl.ds(it * bt, bt), h],
                              kbuf.at[slot], ksem.at[slot]).wait()
        pltpu.make_async_copy(vp_hbm.at[b, pl.ds(it * bt, bt), h],
                              vbuf.at[slot], vsem.at[slot]).wait()

    @pl.when(live)
    def _block():
        qf = q_ref[0, 0]                                   # (G, Dh) f32
        codes_k = unpack_int4(kbuf[slot], Dh, axis=-1)     # (bt, Dh) int8
        codes_v = unpack_int4(vbuf[slot], Dh, axis=-1)
        ks = ks_ref[0, :, 0]                               # (bt,) f32
        vs = vs_ref[0, :, 0]
        kf = codes_k.astype(jnp.float32) * ks[:, None]
        vf = codes_v.astype(jnp.float32) * vs[:, None]
        s = jnp.dot(qf, kf.T, preferred_element_type=jnp.float32)  # (G, bt)
        kpos = it * bt + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_ref[...]                                # (G, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p, vf, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(it == n_t - 1)
    def _emit():
        o_ref[0, 0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


def _pad_t(arr, t_pad):
    if arr.shape[1] == t_pad:
        return arr
    pad = [(0, 0)] * arr.ndim
    pad[1] = (0, t_pad - arr.shape[1])
    return jnp.pad(arr, pad)


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def packed_decode_attention(
    q: jnp.ndarray,     # (B, 1, H, Dh)
    k_p: jnp.ndarray,   # (B, T, Hkv, ceil(Dh/2)) uint8 packed codes
    v_p: jnp.ndarray,   # (B, T, Hkv, ceil(Dh/2)) uint8
    k_s: jnp.ndarray,   # (B, T, Hkv) f32 per-row scales
    v_s: jnp.ndarray,   # (B, T, Hkv) f32
    length: jnp.ndarray,  # (B,) live cache length per slot
    *,
    bt: int = 64,
    interpret: bool = False,
) -> jnp.ndarray:
    B, C, H, Dh = q.shape
    assert C == 1, "kernel path is decode-only (one query row per slot)"
    T, Hkv, Dhp = k_p.shape[1], k_p.shape[2], k_p.shape[3]
    assert H % Hkv == 0
    G = H // Hkv
    n_t = max(1, -(-T // bt))
    t_pad = n_t * bt

    k_p = _pad_t(k_p, t_pad)
    v_p = _pad_t(v_p, t_pad)
    k_s = _pad_t(k_s, t_pad)
    v_s = _pad_t(v_s, t_pad)

    scale = 1.0 / np.sqrt(Dh)
    qf = (q.astype(jnp.float32) * scale)[:, 0].reshape(B, Hkv, G, Dh)

    def q_idx(bh, it):
        return (bh // Hkv, bh % Hkv, 0, 0)

    def s_idx(bh, it):
        return (bh // Hkv, it, bh % Hkv)

    out = pl.pallas_call(
        functools.partial(_decode_kernel, bt=bt, n_t=n_t, Dh=Dh, Hkv=Hkv),
        grid=(B * Hkv, n_t),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),           # lengths (B,)
            pl.BlockSpec((1, 1, G, Dh), q_idx),              # q (f32, scaled)
            pl.BlockSpec((1, bt, 1), s_idx),                 # k scales
            pl.BlockSpec((1, bt, 1), s_idx),                 # v scales
            pl.BlockSpec(memory_space=pltpu.ANY),            # k packed (HBM)
            pl.BlockSpec(memory_space=pltpu.ANY),            # v packed (HBM)
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dh), q_idx),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dh), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((2, bt, Dhp), jnp.uint8),   # k tile double buffer
            pltpu.VMEM((2, bt, Dhp), jnp.uint8),   # v tile double buffer
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.VMEM((G, 1), jnp.float32),       # m
            pltpu.VMEM((G, 1), jnp.float32),       # l
            pltpu.VMEM((G, Dh), jnp.float32),      # acc
        ],
        interpret=interpret,
        name="logicsparse_packed_decode_attention",
    )(length.astype(jnp.int32), qf, k_s, v_s, k_p, v_p)
    return out.reshape(B, 1, H, Dh).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("bt", "packed"))
def tiled_packed_attention(
    q: jnp.ndarray,        # (B, C, H, Dh) query rows (decode C=1, prefill C>1)
    k_c: jnp.ndarray,      # packed uint8 (B, T, Hkv, ceil(Dh/2)) or int8 codes
    v_c: jnp.ndarray,      #   (B, T, Hkv, Dh) when packed=False
    k_s: jnp.ndarray,      # (B, T, Hkv) f32
    v_s: jnp.ndarray,      # (B, T, Hkv) f32
    lengths: jnp.ndarray,  # (B, C) live length per query row
    *,
    bt: int = 64,
    packed: bool = True,
) -> jnp.ndarray:
    """jnp twin of the kernel, batched over the chunk axis C.

    Tile-by-tile online softmax with the exact op order of
    :func:`packed_decode_attention`; a tile that is dead for a given
    (b, c) row leaves that row's (m, l, acc) state untouched via a
    ``where`` select, mirroring the kernel's ``pl.when`` skip.
    """
    B, C, H, Dh = q.shape
    T, Hkv = k_c.shape[1], k_c.shape[2]
    G = H // Hkv
    n_t = max(1, -(-T // bt))
    t_pad = n_t * bt

    k_c = _pad_t(k_c, t_pad)
    v_c = _pad_t(v_c, t_pad)
    k_s = _pad_t(k_s, t_pad)
    v_s = _pad_t(v_s, t_pad)

    scale = 1.0 / np.sqrt(Dh)
    qf = (q.astype(jnp.float32) * scale).reshape(B, C, Hkv, G, Dh)

    m = jnp.full((B, C, Hkv, G), NEG_INF, jnp.float32)
    l = jnp.zeros((B, C, Hkv, G), jnp.float32)
    acc = jnp.zeros((B, C, Hkv, G, Dh), jnp.float32)

    for it in range(n_t):
        tile_k = jax.lax.slice_in_dim(k_c, it * bt, (it + 1) * bt, axis=1)
        tile_v = jax.lax.slice_in_dim(v_c, it * bt, (it + 1) * bt, axis=1)
        if packed:
            codes_k = unpack_int4(tile_k, Dh, axis=-1)
            codes_v = unpack_int4(tile_v, Dh, axis=-1)
        else:
            codes_k, codes_v = tile_k, tile_v
        ks = jax.lax.slice_in_dim(k_s, it * bt, (it + 1) * bt, axis=1)
        vs = jax.lax.slice_in_dim(v_s, it * bt, (it + 1) * bt, axis=1)
        kf = codes_k.astype(jnp.float32) * ks[..., None]   # (B, bt, Hkv, Dh)
        vf = codes_v.astype(jnp.float32) * vs[..., None]
        s = jnp.einsum("bcHgd,btHd->bcHgt", qf, kf,
                       preferred_element_type=jnp.float32)
        kpos = it * bt + jnp.arange(bt, dtype=jnp.int32)
        valid = kpos[None, None, :] < lengths[:, :, None]  # (B, C, bt)
        s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bcHgt,btHd->bcHgd", p, vf,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        live = (it * bt) < lengths                         # (B, C)
        m = jnp.where(live[:, :, None, None], m_new, m)
        l = jnp.where(live[:, :, None, None], l_new, l)
        acc = jnp.where(live[:, :, None, None, None], acc_new, acc)

    out = acc / jnp.maximum(l, 1e-30)[..., None]
    # head order h = kv_head * G + g matches q's reshape above, so a
    # plain reshape restores (B, C, H, Dh)
    return out.reshape(B, C, H, Dh).astype(q.dtype)
