"""Public op: flash attention with XLA-chunked backward.

Forward runs the Pallas kernel (VMEM-fused, no score tensors in HBM);
backward differentiates the chunked-XLA oracle under remat (the usual
recompute-in-backward pattern — the fwd kernel's savings carry the fwd and
the recompute inside bwd; a fused bwd kernel is future work, noted in
EXPERIMENTS.md §Perf)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...models.layers import chunked_attention
from .kernel import flash_attention as _fwd_kernel


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention(q, k, v, causal: bool = True, interpret: bool = False):
    return _fwd_kernel(q, k, v, causal=causal, interpret=interpret)


def _fwd(q, k, v, causal, interpret):
    return flash_attention(q, k, v, causal, interpret), (q, k, v)


def _bwd(causal, interpret, res, g):
    q, k, v = res

    def f(q, k, v):
        return chunked_attention(q, k, v, causal=causal)

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
