"""Flash attention (forward) — Pallas TPU kernel with GQA + causal masking.

Why it exists in a LogicSparse repro: the dry-run roofline shows attention
*score* tensors (Q·Kᵀ, softmax, P·V) dominating HBM traffic for the 4k/32k
shapes — XLA materialises them, a fused kernel keeps them in VMEM.  This
kernel is the memory-term hillclimb for the train/prefill cells; the
analytic "flash adjustment" in the dry-run roofline is backed by this
implementation (validated in interpret mode against the jnp oracle).

Grid: (B·H, Tq/bq, Tk/bk) with ik innermost; online-softmax state
(m, l, acc) lives in VMEM scratch; the output tile is emitted once at the
final k-block.  GQA is handled in the kv index maps (kv head = h // G).
Fully-masked (future) k-blocks are skipped with ``pl.when``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, bq: int, bk: int, n_k: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = True
    if causal:
        # k-block strictly in the future of every q row -> skip entirely
        run = (ik * bk) <= (iq * bq + bq - 1)

    @pl.when(run if causal else True)
    def _block():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, Dh)
        k = k_ref[0].astype(jnp.float32)                  # (bk, Dh)
        v = v_ref[0].astype(jnp.float32)                  # (bk, Dh)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)
        if causal:
            qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]                               # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _emit():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "bq", "bk", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,  # (B, Tq, H, Dh)
    k: jnp.ndarray,  # (B, Tk, Hkv, Dh)
    v: jnp.ndarray,  # (B, Tk, Hkv, Dh)
    *,
    causal: bool = True,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Tq, H, Dh = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    assert H % Hkv == 0 and Tq % bq == 0 and Tk % bk == 0
    G = H // Hkv
    scale = 1.0 / np.sqrt(Dh)

    qr = q.transpose(0, 2, 1, 3).reshape(B * H, Tq, Dh)
    kr = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Tk, Dh)
    vr = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Tk, Dh)
    n_k = Tk // bk

    def kv_idx(bh, iq, ik):
        return ((bh // H) * Hkv + (bh % H) // G, ik, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, bq=bq, bk=bk,
                          n_k=n_k),
        grid=(B * H, Tq // bq, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, Dh), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, Dh), kv_idx),
            pl.BlockSpec((1, bk, Dh), kv_idx),
        ],
        out_specs=pl.BlockSpec((1, bq, Dh), lambda bh, iq, ik: (bh, iq, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, Dh), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((B * H, Tq, Dh), q.dtype),
        interpret=interpret,
        name="logicsparse_flash_attention_fwd",
    )(qr, kr, vr)
    return out.reshape(B, H, Tq, Dh).transpose(0, 2, 1, 3)
