"""jnp oracle for the flash attention kernel (naive softmax attention)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal: bool = True):
    B, Tq, H, Dh = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    kq = jnp.repeat(k, G, axis=2)
    vq = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bthd,bshd->bhts", q.astype(jnp.float32),
                   kq.astype(jnp.float32)) / np.sqrt(Dh)
    if causal:
        mask = jnp.tril(jnp.ones((Tq, Tk), bool), k=Tk - Tq)
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhts,bshd->bthd", p, vq.astype(jnp.float32))
    return o.astype(q.dtype)
