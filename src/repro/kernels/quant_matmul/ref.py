"""Pure-jnp oracle for the quantised matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp


def quant_matmul_ref(x, w_q, scales, out_dtype=jnp.float32):
    w = w_q.astype(jnp.float32) * scales.astype(jnp.float32)[None, :]
    return jnp.dot(x.astype(jnp.float32), w).astype(out_dtype)
