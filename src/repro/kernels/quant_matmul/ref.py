"""Pure-jnp oracle for the quantised matmul kernel (epilogue included)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from ..sparse_matmul.kernel import ACTIVATIONS, apply_activation


def quant_matmul_ref(x, w_q, scales, bias=None,
                     activation: Optional[str] = None, out_dtype=jnp.float32):
    """y = act(x @ dequant(W) + b), all in f32 — identical formulas to the
    kernel's fused emit step (same ACTIVATIONS table)."""
    w = w_q.astype(jnp.float32) * scales.astype(jnp.float32)[None, :]
    y = jnp.dot(x.astype(jnp.float32), w)
    if bias is not None:
        y = y + bias.astype(jnp.float32)[None, :]
    if activation is not None:
        y = apply_activation(y, activation)
    return y.astype(out_dtype)
