"""Public op: quantised linear over a QuantizedTensor (or bit-packed
PackedTensor) weight."""
from __future__ import annotations

from typing import Optional, Union

import jax.numpy as jnp

from ...core.quant import PackedTensor, QuantizedTensor
from .kernel import quant_matmul
from .ref import quant_matmul_ref


def quant_linear(
    x: jnp.ndarray,
    qt: Union[QuantizedTensor, PackedTensor],
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    bias: Optional[jnp.ndarray] = None,
    activation: Optional[str] = None,
    out_dtype=jnp.float32,
    interpret: bool = False,
    use_kernel: bool = True,
) -> jnp.ndarray:
    """y = act(x @ dequant(W) + b). x may be (..., K); bias/activation ride
    the kernel's fused emit-step epilogue (f32, same formulas as the jnp
    oracle).

    A :class:`PackedTensor` weight (int4 codes two per byte) rides the
    kernel's packed prologue when packed along an even K with an even bk
    tile; otherwise it is unpacked at trace time into the identical int8
    path — bitwise-equal numerics either way.
    """
    packed_kernel = False
    if isinstance(qt, PackedTensor):
        K, N = qt.shape
        if use_kernel and qt.axis % len(qt.shape) == 0 and K % 2 == 0 \
                and bk % 2 == 0:
            packed_kernel = True
            values, scales = qt.data, qt.scales.reshape(N)
        else:
            qt = qt.to_quantized()
    if not packed_kernel:
        K, N = qt.values.shape
        values, scales = qt.values, qt.scales.reshape(N)
    lead = x.shape[:-1]
    xm = x.reshape(-1, K)
    if use_kernel:
        M = xm.shape[0]
        pad = (-M) % bm
        if pad:
            xm = jnp.pad(xm, ((0, pad), (0, 0)))
        y = quant_matmul(xm, values, scales, bias, bm=bm, bn=bn, bk=bk,
                         activation=activation, out_dtype=out_dtype,
                         interpret=interpret, packed=packed_kernel)
        if pad:
            y = y[:M]
    else:
        y = quant_matmul_ref(xm, values, scales, bias=bias,
                             activation=activation, out_dtype=out_dtype)
    return y.reshape(*lead, N)
