"""Public op: quantised linear over a QuantizedTensor weight."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from ...core.quant import QuantizedTensor
from .kernel import quant_matmul
from .ref import quant_matmul_ref


def quant_linear(
    x: jnp.ndarray,
    qt: QuantizedTensor,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    bias: Optional[jnp.ndarray] = None,
    activation: Optional[str] = None,
    out_dtype=jnp.float32,
    interpret: bool = False,
    use_kernel: bool = True,
) -> jnp.ndarray:
    """y = act(x @ dequant(W) + b). x may be (..., K); bias/activation ride
    the kernel's fused emit-step epilogue (f32, same formulas as the jnp
    oracle)."""
    K, N = qt.values.shape
    lead = x.shape[:-1]
    xm = x.reshape(-1, K)
    scales = qt.scales.reshape(N)
    if use_kernel:
        M = xm.shape[0]
        pad = (-M) % bm
        if pad:
            xm = jnp.pad(xm, ((0, pad), (0, 0)))
        y = quant_matmul(xm, qt.values, scales, bias, bm=bm, bn=bn, bk=bk,
                         activation=activation, out_dtype=out_dtype,
                         interpret=interpret)
        if pad:
            y = y[:M]
    else:
        y = quant_matmul_ref(xm, qt.values, scales, bias=bias,
                             activation=activation, out_dtype=out_dtype)
    return y.reshape(*lead, N)
