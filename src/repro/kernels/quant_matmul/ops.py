"""Public op: quantised linear over a QuantizedTensor weight."""
from __future__ import annotations

import jax.numpy as jnp

from ...core.quant import QuantizedTensor
from .kernel import quant_matmul
from .ref import quant_matmul_ref


def quant_linear(
    x: jnp.ndarray,
    qt: QuantizedTensor,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    out_dtype=jnp.float32,
    interpret: bool = False,
    use_kernel: bool = True,
) -> jnp.ndarray:
    """y = x @ dequant(W). x may be (..., K)."""
    K, N = qt.values.shape
    lead = x.shape[:-1]
    xm = x.reshape(-1, K)
    scales = qt.scales.reshape(N)
    if use_kernel:
        M = xm.shape[0]
        pad = (-M) % bm
        if pad:
            xm = jnp.pad(xm, ((0, pad), (0, 0)))
        y = quant_matmul(xm, qt.values, scales, bm=bm, bn=bn, bk=bk,
                         out_dtype=out_dtype, interpret=interpret)
        if pad:
            y = y[:M]
    else:
        y = quant_matmul_ref(xm, qt.values, scales, out_dtype=out_dtype)
    return y.reshape(*lead, N)
