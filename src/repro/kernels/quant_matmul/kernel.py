"""Quantised dense matmul: int8 weights × f32/bf16 activations, fused dequant.

The QNN datapath for layers the DSE keeps *dense* (folded): weights stream
from HBM as int8 (halving/quartering memory traffic vs bf16/f32 — these
layers are memory-bound by construction, so the paper's quantisation is a
direct roofline win), dequantised in-register against the per-output-channel
scale, accumulated in f32 on the MXU.

Grid: (m, n, k) with k innermost; the (bm, bn) f32 accumulator lives in
VMEM scratch and is emitted once at k == n_k - 1, through the same fused
**bias + activation** epilogue as the sparse kernel (f32: ``acc*scale + b``
then ``act``) — a whole ``act(x @ dequant(W) + b)`` layer is one launch,
with no extra HBM round-trip for the epilogue.  The formulas are imported
from :data:`repro.kernels.sparse_matmul.kernel.ACTIVATIONS`, so the quant
and sparse paths stay numerically symmetric.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..sparse_matmul.kernel import ACTIVATIONS, _check_activation, _unpack_int4_rows

__all__ = ["quant_matmul"]


def _kernel(x_ref, w_ref, s_ref, b_ref, o_ref, acc_ref, *, n_k: int,
            activation: Optional[str], packed: bool = False):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...]
    if packed:
        # bit-packed int4 container: (bk/2, bn) uint8 tile decoded to
        # (bk, bn) int8 codes in-register — HBM->VMEM at half the bytes
        w = _unpack_int4_rows(w)
    w = w.astype(jnp.float32)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _emit():
        scale = s_ref[0].astype(jnp.float32)  # (bn,) per-out-channel
        out = acc_ref[...] * scale[None, :] + b_ref[0].astype(jnp.float32)[None, :]
        if activation is not None:
            out = ACTIVATIONS[activation](out)
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "interpret", "out_dtype", "activation",
                     "packed"),
)
def quant_matmul(
    x: jnp.ndarray,      # (M, K) f32/bf16
    w_q: jnp.ndarray,    # (K, N) int8 — or (K/2, N) uint8 when packed
    scales: jnp.ndarray, # (N,)   f32
    bias: Optional[jnp.ndarray] = None,  # (N,) f32 or None
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
    out_dtype=jnp.float32,
    activation: Optional[str] = None,
    packed: bool = False,
) -> jnp.ndarray:
    """y = act(x @ dequant(W) + b) in one launch (epilogue fused at emit).

    ``packed=True`` takes the bit-packed int4 container: ``w_q`` is uint8
    ``(K/2, N)`` with two codes per byte along K (K and bk must be even);
    the kernel decodes in-register, so numerics are bitwise identical to
    the int8 container — only the weight bytes streamed from HBM halve.
    """
    _check_activation(activation)
    M, K = x.shape
    if packed:
        if w_q.dtype != jnp.uint8:
            raise ValueError(
                f"packed=True needs a uint8 int4x2 container, got {w_q.dtype}")
        if K % 2 or bk % 2:
            raise ValueError(
                f"packed quant_matmul needs even K and bk, got K={K} bk={bk}")
        K2, N = w_q.shape[0] * 2, w_q.shape[1]
    else:
        K2, N = w_q.shape
    assert K == K2 and scales.shape == (N,)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    if bias is None:
        bias = jnp.zeros((N,), jnp.float32)
    n_k = K // bk
    w_bk = bk // 2 if packed else bk
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, activation=activation,
                          packed=packed),
        grid=(M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((w_bk, bn), lambda m, n, k: (k, n)),
            pl.BlockSpec((1, bn), lambda m, n, k: (0, n)),
            pl.BlockSpec((1, bn), lambda m, n, k: (0, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        interpret=interpret,
        name="logicsparse_quant_matmul",
    )(x, w_q, scales.reshape(1, N), bias.reshape(1, N).astype(jnp.float32))
