"""Quantised dense matmul: int8 weights × f32/bf16 activations, fused dequant.

The QNN datapath for layers the DSE keeps *dense* (folded): weights stream
from HBM as int8 (halving/quartering memory traffic vs bf16/f32 — these
layers are memory-bound by construction, so the paper's quantisation is a
direct roofline win), dequantised in-register against the per-output-channel
scale, accumulated in f32 on the MXU.

Grid: (m, n, k) with k innermost; the (bm, bn) f32 accumulator lives in
VMEM scratch and is emitted once at k == n_k - 1, through the same fused
**bias + activation** epilogue as the sparse kernel (f32: ``acc*scale + b``
then ``act``) — a whole ``act(x @ dequant(W) + b)`` layer is one launch,
with no extra HBM round-trip for the epilogue.  The formulas are imported
from :data:`repro.kernels.sparse_matmul.kernel.ACTIVATIONS`, so the quant
and sparse paths stay numerically symmetric.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..sparse_matmul.kernel import (
    ACTIVATIONS,
    _check_activation,
    _check_pool,
    _decode_rows,
    _im2col_tile,
    _packed_ratio,
    _pool_tile,
    _unpack_int4_rows,
    apply_activation,
)

__all__ = ["quant_matmul", "quant_conv"]


def _kernel(x_ref, w_ref, s_ref, b_ref, o_ref, acc_ref, *, n_k: int,
            activation, packed=False):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...]
    if packed:
        # bit-packed sub-byte container: (bk/ratio, bn) uint8 tile decoded
        # to (bk, bn) int8 codes in-register — HBM->VMEM at a fraction of
        # the bytes
        w = _decode_rows(w, packed)
    w = w.astype(jnp.float32)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _emit():
        scale = s_ref[0].astype(jnp.float32)  # (bn,) per-out-channel
        out = acc_ref[...] * scale[None, :] + b_ref[0].astype(jnp.float32)[None, :]
        out = apply_activation(out, activation)
        o_ref[...] = out.astype(o_ref.dtype)


def _kernel_packed_db(x_ref, w_hbm, s_ref, b_ref, o_ref, acc_ref, w_buf,
                      w_sems, *, n_n: int, n_k: int, w_bk: int, bn: int,
                      activation, packed=True):
    """Packed-container (m, n, k) step with a double-buffered prologue.

    The uint8 (K/ratio, N) container stays in HBM; each step's (w_bk, bn)
    tile is streamed into a two-slot VMEM buffer by hand, with the next
    (n, k) step's DMA started before this step's wait — the sub-byte
    decode overlaps the next tile's copy.  Steps are linearised as
    ``n * n_k + k`` (the grid's own iteration order), so the prefetch
    crosses n-boundaries too.
    """
    n = pl.program_id(1)
    k = pl.program_id(2)
    step = n * n_k + k
    slot = jax.lax.rem(step, 2)

    def _start(s2, slot2):
        n2 = jax.lax.div(s2, n_k)
        k2 = jax.lax.rem(s2, n_k)
        pltpu.make_async_copy(
            w_hbm.at[pl.ds(k2 * w_bk, w_bk), pl.ds(n2 * bn, bn)],
            w_buf.at[slot2], w_sems.at[slot2]).start()

    @pl.when(step == 0)
    def _warm():
        _start(0, 0)

    @pl.when(step + 1 < n_n * n_k)
    def _prefetch():
        _start(step + 1, 1 - slot)

    pltpu.make_async_copy(
        w_hbm.at[pl.ds(k * w_bk, w_bk), pl.ds(n * bn, bn)],
        w_buf.at[slot], w_sems.at[slot]).wait()

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    w = _decode_rows(w_buf[slot], packed).astype(jnp.float32)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _emit():
        scale = s_ref[0].astype(jnp.float32)
        out = acc_ref[...] * scale[None, :] + b_ref[0].astype(jnp.float32)[None, :]
        out = apply_activation(out, activation)
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "interpret", "out_dtype", "activation",
                     "packed"),
)
def quant_matmul(
    x: jnp.ndarray,      # (M, K) f32/bf16
    w_q: jnp.ndarray,    # (K, N) int8 — or (K/2, N) uint8 when packed
    scales: jnp.ndarray, # (N,)   f32
    bias: Optional[jnp.ndarray] = None,  # (N,) f32 or None
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
    out_dtype=jnp.float32,
    activation=None,
    packed=False,
) -> jnp.ndarray:
    """y = act(x @ dequant(W) + b) in one launch (epilogue fused at emit).

    ``packed`` takes a bit-packed sub-byte container: ``w_q`` is uint8
    ``(K/ratio, N)`` with ratio codes per byte along K (K and bk must
    divide by the ratio) — ratio 2 for ``True``/"int4x2", 4 for "int2x4";
    the kernel decodes in-register, so numerics are bitwise identical to
    the int8 container — only the weight bytes streamed from HBM shrink.
    """
    _check_activation(activation)
    M, K = x.shape
    ratio = _packed_ratio(packed)
    if packed:
        if w_q.dtype != jnp.uint8:
            raise ValueError(
                f"packed={packed!r} needs a uint8 container, got {w_q.dtype}")
        if K % ratio or bk % ratio:
            raise ValueError(
                f"packed={packed!r} quant_matmul needs K and bk divisible "
                f"by {ratio}, got K={K} bk={bk}")
        K2, N = w_q.shape[0] * ratio, w_q.shape[1]
    else:
        K2, N = w_q.shape
    assert K == K2 and scales.shape == (N,)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    if bias is None:
        bias = jnp.zeros((N,), jnp.float32)
    n_k = K // bk
    w_bk = bk // ratio
    if packed:
        # hand-driven two-slot double buffer: the next tile's HBM->VMEM
        # DMA overlaps this tile's sub-byte decode + MXU pass
        kernel = functools.partial(_kernel_packed_db, n_n=N // bn, n_k=n_k,
                                   w_bk=w_bk, bn=bn, activation=activation,
                                   packed=packed)
        w_spec = pl.BlockSpec(memory_space=pltpu.ANY)
        scratch = [pltpu.VMEM((bm, bn), jnp.float32),
                   pltpu.VMEM((2, w_bk, bn), jnp.uint8),
                   pltpu.SemaphoreType.DMA((2,))]
    else:
        kernel = functools.partial(_kernel, n_k=n_k, activation=activation,
                                   packed=False)
        w_spec = pl.BlockSpec((w_bk, bn), lambda m, n, k: (k, n))
        scratch = [pltpu.VMEM((bm, bn), jnp.float32)]
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            w_spec,
            pl.BlockSpec((1, bn), lambda m, n, k: (0, n)),
            pl.BlockSpec((1, bn), lambda m, n, k: (0, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        scratch_shapes=scratch,
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        interpret=interpret,
        name="logicsparse_quant_matmul",
    )(x, w_q, scales.reshape(1, N), bias.reshape(1, N).astype(jnp.float32))


def _conv_kernel(x_ref, w_ref, s_ref, b_ref, o_ref, acc_ref, patch_ref, *,
                 n_k: int, activation, packed,
                 conv, strides, dilation, pool):
    """Fused-conv (m, n, k) step: m is the batch index; the (Ho*Wo, K)
    patch tile is built in VMEM at the image's first step and each k step
    reads its (Ho*Wo, bk) activation tile as a dynamic lane slice."""
    kh, kw, Ho, Wo, bk = conv
    n = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when((n == 0) & (k == 0))
    def _patches():
        patch_ref[...] = _im2col_tile(x_ref[0], kh, kw, Ho, Wo,
                                      strides, dilation)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xt = patch_ref[:, pl.ds(k * bk, bk)].astype(jnp.float32)
    w = w_ref[...]
    if packed:
        w = _decode_rows(w, packed)
    acc_ref[...] += jnp.dot(xt, w.astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _emit():
        scale = s_ref[0].astype(jnp.float32)
        out = acc_ref[...] * scale[None, :] + b_ref[0].astype(jnp.float32)[None, :]
        out = apply_activation(out, activation)
        t = out.reshape(Ho, Wo, out.shape[-1])
        if pool is not None:
            t = _pool_tile(t, pool)
        o_ref[0] = t.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("kernel_hw", "bn", "bk", "strides", "dilation",
                     "interpret", "out_dtype", "activation", "packed",
                     "pool"),
)
def quant_conv(
    x: jnp.ndarray,       # (B, H, W, cin) NHWC, pre-padded (VALID geometry)
    w_q: jnp.ndarray,     # (K, N) int8 — or (K/2, N) uint8 when packed
    scales: jnp.ndarray,  # (N,) f32
    bias: Optional[jnp.ndarray] = None,
    *,
    kernel_hw,
    bn: Optional[int] = None,
    bk: Optional[int] = None,
    strides: Tuple[int, int] = (1, 1),
    dilation: Tuple[int, int] = (1, 1),
    interpret: bool = False,
    out_dtype=jnp.float32,
    activation=None,
    packed=False,
    pool=None,
) -> jnp.ndarray:
    """Fused-im2col quantised conv: pool(act(conv(x, dequant(W)) + b)).

    The dense-quantised twin of
    :func:`repro.kernels.sparse_matmul.kernel.block_sparse_conv`: same
    in-kernel patch construction (static ``strides``/``dilation`` baked
    into the patch gather; the input must already carry any explicit
    zero-pad) and pooled emit, over the quant kernel's (m, n, k)
    accumulation.  ``bn``/``bk`` default to 128 when the dim divides,
    else the whole dim (interpret-only shapes, same rule as the linear
    dispatch path).  Output is bitwise identical to
    im2col + :func:`quant_matmul` at the same tiles.
    """
    _check_activation(activation)
    if x.ndim != 4:
        raise ValueError(f"quant_conv expects NHWC input, got {x.shape}")
    B, H, W, cin = x.shape
    kh, kw = kernel_hw
    strides = (int(strides[0]), int(strides[1]))
    dilation = (int(dilation[0]), int(dilation[1]))
    ekh = (kh - 1) * dilation[0] + 1
    ekw = (kw - 1) * dilation[1] + 1
    Ho = (H - ekh) // strides[0] + 1
    Wo = (W - ekw) // strides[1] + 1
    if Ho < 1 or Wo < 1:
        raise ValueError(
            f"conv kernel {tuple(kernel_hw)} does not fit the {H}x{W} input")
    _check_pool(pool, Ho, Wo)
    K = cin * kh * kw
    ratio = _packed_ratio(packed)
    if packed:
        if w_q.dtype != jnp.uint8:
            raise ValueError(
                f"packed={packed!r} needs a uint8 container, got {w_q.dtype}")
        if K % ratio:
            raise ValueError(
                f"packed={packed!r} quant_conv needs K divisible by "
                f"{ratio}, got K={K}")
        K2, N = w_q.shape[0] * ratio, w_q.shape[1]
    else:
        K2, N = w_q.shape
    if K != K2:
        raise ValueError(
            f"im2col K={K} (cin*kh*kw) != weight rows {K2}")
    if bn is None or N % bn:
        bn = 128 if N % 128 == 0 else N
    if bk is None or K % bk or (packed and bk % ratio):
        bk = 128 if K % 128 == 0 else K
    if bias is None:
        bias = jnp.zeros((N,), jnp.float32)
    n_k = K // bk
    w_bk = bk // ratio
    Hp, Wp = (Ho // pool[1], Wo // pool[1]) if pool is not None else (Ho, Wo)
    return pl.pallas_call(
        functools.partial(_conv_kernel, n_k=n_k, activation=activation,
                          packed=packed, conv=(kh, kw, Ho, Wo, bk),
                          strides=strides, dilation=dilation, pool=pool),
        grid=(B, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((1, H, W, cin), lambda m, n, k: (m, 0, 0, 0)),
            pl.BlockSpec((w_bk, bn), lambda m, n, k: (k, n)),
            pl.BlockSpec((1, bn), lambda m, n, k: (0, n)),
            pl.BlockSpec((1, bn), lambda m, n, k: (0, n)),
        ],
        out_specs=pl.BlockSpec((1, Hp, Wp, bn),
                               lambda m, n, k: (m, 0, 0, n)),
        scratch_shapes=[pltpu.VMEM((Ho * Wo, bn), jnp.float32),
                        pltpu.VMEM((Ho * Wo, K), x.dtype)],
        out_shape=jax.ShapeDtypeStruct((B, Hp, Wp, N), out_dtype),
        interpret=interpret,
        name="logicsparse_quant_conv",
    )(x, w_q, scales.reshape(1, N), bias.reshape(1, N).astype(jnp.float32))
